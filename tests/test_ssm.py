"""Mamba2/SSD: chunked form vs sequential recurrence oracle; decode parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.yoco_linear import DEFAULT_YOCO
from repro.models import ssm

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:                                   # pragma: no cover
    HAVE_HYP = False


def _rand_ssd_inputs(key, b=2, s=64, h=4, p=8, g=1, n=16):
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)) - 1.0)
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bmat = jax.random.normal(ks[3], (b, s, g, n)) * 0.5
    cmat = jax.random.normal(jax.random.fold_in(ks[3], 1), (b, s, g, n)) * 0.5
    return x, dt, a, bmat, cmat


@pytest.mark.parametrize('chunk', [8, 16, 64])
def test_ssd_chunked_matches_sequential(chunk):
    x, dt, a, b, c = _rand_ssd_inputs(jax.random.key(0))
    y, fin = ssm.ssd_chunked(x, dt, a, b, c, chunk)
    y_ref, fin_ref = ssm.ssd_reference(x, dt, a, b, c)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(fin), np.asarray(fin_ref),
                               rtol=2e-4, atol=2e-4)


def test_ssd_chunked_with_initial_state():
    key = jax.random.key(1)
    x, dt, a, b, c = _rand_ssd_inputs(key, s=32)
    init = jax.random.normal(jax.random.fold_in(key, 9), (2, 4, 8, 16))
    y, fin = ssm.ssd_chunked(x, dt, a, b, c, 16, init_state=init)
    y_ref, fin_ref = ssm.ssd_reference(x, dt, a, b, c, init_state=init)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(fin), np.asarray(fin_ref),
                               rtol=2e-4, atol=2e-4)


def test_ssd_state_continuity_across_segments():
    """Prefill in two halves == prefill in one go (chunked-prefill path)."""
    key = jax.random.key(2)
    x, dt, a, b, c = _rand_ssd_inputs(key, s=64)
    y_full, fin_full = ssm.ssd_chunked(x, dt, a, b, c, 16)
    y1, s1 = ssm.ssd_chunked(x[:, :32], dt[:, :32], a, b[:, :32], c[:, :32], 16)
    y2, s2 = ssm.ssd_chunked(x[:, 32:], dt[:, 32:], a, b[:, 32:], c[:, 32:],
                             16, init_state=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(fin_full),
                               rtol=2e-4, atol=2e-4)


def test_mamba2_block_decode_matches_forward():
    cfg = configs.get('mamba2-780m', smoke=True)
    p = ssm.init_mamba2(jax.random.key(3), cfg)
    x = jax.random.normal(jax.random.key(4), (2, 24, cfg.d_model),
                          jnp.float32)
    y_full, _ = ssm.mamba2_forward(p, x, cfg, DEFAULT_YOCO)
    state = ssm.init_ssm_state(cfg, 2)
    ys = []
    for t in range(24):
        y_t, state = ssm.mamba2_decode(p, x[:, t:t+1], cfg, DEFAULT_YOCO,
                                       state=state)
        ys.append(y_t)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec, np.float32),
                               np.asarray(y_full, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_mamba2_prefill_then_decode_continuity():
    cfg = configs.get('mamba2-780m', smoke=True)
    p = ssm.init_mamba2(jax.random.key(5), cfg)
    x = jax.random.normal(jax.random.key(6), (1, 33, cfg.d_model), jnp.float32)
    y_full, _ = ssm.mamba2_forward(p, x, cfg, DEFAULT_YOCO)
    state = ssm.init_ssm_state(cfg, 1)
    _, state = ssm.mamba2_forward(p, x[:, :32], cfg, DEFAULT_YOCO, state=state)
    y_t, _ = ssm.mamba2_decode(p, x[:, 32:33], cfg, DEFAULT_YOCO, state=state)
    np.testing.assert_allclose(np.asarray(y_t, np.float32),
                               np.asarray(y_full[:, 32:33], np.float32),
                               rtol=5e-2, atol=5e-2)


if HAVE_HYP:
    @given(st.integers(0, 10**6), st.sampled_from([8, 16, 32]),
           st.integers(1, 3))
    @settings(max_examples=15, deadline=None)
    def test_prop_ssd_chunk_invariance(seed, chunk, b):
        """Output must not depend on the chunk size (pure reassociation)."""
        key = jax.random.key(seed)
        x, dt, a, bm, cm = _rand_ssd_inputs(key, b=b, s=32)
        y1, f1 = ssm.ssd_chunked(x, dt, a, bm, cm, chunk)
        y2, f2 = ssm.ssd_chunked(x, dt, a, bm, cm, 32)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=3e-4, atol=3e-4)
        np.testing.assert_allclose(np.asarray(f1), np.asarray(f2),
                                   rtol=3e-4, atol=3e-4)
