"""Circuit-behavioral simulator calibration against the paper's Fig. 5 /
§III-C / §IV-C numbers."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import analog


def test_lsb_constant_matches_paper():
    # paper: 1 LSB = 3.52 mV at VDD = 0.9 V, 8 bits
    assert abs(analog.LSB - 3.52e-3) < 0.02e-3


def test_ideal_input_conversion_is_eq2():
    codes = jnp.arange(256)
    v = analog.input_conversion_ideal(codes)
    np.testing.assert_allclose(np.asarray(v),
                               np.arange(256) / 255.0 * analog.VDD,
                               rtol=1e-6)


def test_input_conversion_inl_dnl_under_2lsb():
    """Fig. 5a/b: INL and DNL < 2 LSB over all 256 codes (chip mismatch,
    no thermal noise: that's Fig. 5c)."""
    codes = jnp.arange(256)
    chip = analog.sample_chip(jax.random.key(7))
    v = analog.input_conversion(codes[None, :].repeat(analog.MACRO_ROWS, 0).T,
                                chip)  # (256, rows)
    v = v[:, 0]
    ideal = analog.input_conversion_ideal(codes)
    inl = np.abs(np.asarray(v - ideal)) / analog.LSB
    dnl = np.abs(np.diff(np.asarray(v)) - analog.LSB) / analog.LSB
    assert inl.max() < 2.0, inl.max()
    assert dnl.max() < 2.0, dnl.max()


def test_input_conversion_3sigma_under_1lsb():
    """Fig. 5c: 2K Monte-Carlo, 3-sigma error ~2.25 mV < 1 LSB (3.52 mV)."""
    n = 2000
    keys = jax.random.split(jax.random.key(0), n)
    code = jnp.full((n, 1), 128)

    def one(k):
        k1, k2 = jax.random.split(k)
        chip = analog.sample_chip(k1, rows=1)
        return analog.input_conversion(code[:1], chip, k2)

    vs = jax.vmap(one)(keys)
    ideal = analog.input_conversion_ideal(jnp.array([128]))
    # remove the deterministic bow (it is INL, not random error — Fig. 5b)
    bow = analog.INL_BOW_LSB * analog.LSB * np.sin(np.pi * 128 / 255)
    err = np.asarray(vs).reshape(-1) - float(ideal[0]) - bow
    sigma3 = 3 * err.std()
    assert sigma3 < analog.LSB, (sigma3, analog.LSB)
    assert sigma3 > 0.2 * analog.LSB          # non-trivial noise modeled


def test_mac_error_under_paper_bound():
    """Fig. 5d/e: 8-bit MAC with 128 channels, max error <= 0.68% FS."""
    rows = analog.MACRO_ROWS
    # weight-scan TC: input all-255, weights swept 0..255 (one CB output)
    w_codes = jnp.arange(256)[None, :].repeat(rows, 0)      # (rows, 256)
    x = jnp.full((rows,), 255)
    chip = analog.sample_chip(jax.random.key(3), cbs=256)
    v_in = analog.input_conversion(x, None)                 # noise-free input
    v = analog.macro_mac(v_in, w_codes, chip)
    ideal = analog.macro_mac_ideal(x, w_codes)
    fs = float(jnp.max(jnp.abs(ideal)))
    err = np.abs(np.asarray(v - ideal)) / fs
    assert err.max() <= 0.0068 + 2e-3, err.max()            # paper 0.68%


def test_time_accumulation_error_under_paper_bound():
    """§III-C: VTC-chain accumulation error <= 0.11% of full scale."""
    n_macros = 8
    chip = analog.sample_chip(jax.random.key(5), n_macros_v=n_macros)
    v_parts = jnp.full((n_macros, 32), analog.VDD / 2)
    got = analog.time_accumulate(v_parts, chip, axis=0)
    ideal = jnp.sum(v_parts, axis=0)
    rel = np.abs(np.asarray(got - ideal)) / float(jnp.max(jnp.abs(ideal)))
    assert rel.max() <= 0.0011 + 5e-4, rel.max()


def test_full_vmm_error_under_total_bound():
    """§IV-C: total VMM error < 0.79% of full scale (1024-channel VMM)."""
    key = jax.random.key(11)
    x = jax.random.randint(key, (4, 1024), 0, 256)
    w = jax.random.randint(jax.random.fold_in(key, 1), (1024, 32), 0, 256)
    codes = analog.analog_vmm(x, w, key=jax.random.fold_in(key, 2))
    ideal = analog.analog_vmm_ideal_codes(x, w)
    # error in codes relative to the 8-bit full scale
    rel = np.abs(np.asarray(codes - ideal)) / 255.0
    assert rel.max() <= 0.0079 + 0.004, rel.max()


def test_analog_vmm_ideal_matches_int_matmul():
    key = jax.random.key(13)
    x = jax.random.randint(key, (2, 256), 0, 256)
    w = jax.random.randint(jax.random.fold_in(key, 1), (256, 8), 0, 256)
    codes = analog.analog_vmm(x, w, key=None)     # ideal circuits
    ideal = analog.analog_vmm_ideal_codes(x, w)
    assert int(jnp.max(jnp.abs(codes - ideal))) <= 1   # TDC rounding only


def test_error_model_summary_fields():
    em = analog.error_model_summary()
    assert em['total_bound'] == 0.0079
    assert em['tdc_bits'] == 8
