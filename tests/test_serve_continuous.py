"""Continuous batching end-to-end: a stream of heterogeneous-length
requests admitted / decoded / evicted / re-admitted over the paged KV
cache, under one jit'd decode step — plus the temperature/top-k sampling
path in the serve steps."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.yoco_linear import YocoConfig
from repro.data import synthetic
from repro.launch import serve as SV
from repro.models import model as model_mod
from repro.models.model import ModelRuntime
from repro.runtime import serve_step as SS

ARCH = 'stablelm-1.6b'
# the MLA member of the grid: continuous batching over the paged LATENT
# pool (deepseek-v3 smoke = MLA + MoE + dense prefix)
MLA_ARCH = 'deepseek-v3-671b'


@functools.lru_cache(maxsize=2)
def _reference_model(arch=ARCH):
    """Shared across reference decodes: params + jitted steps are identical
    for every request (same cfg, same shapes)."""
    cfg = configs.get(arch, smoke=True)
    yoco, rt = YocoConfig(mode='bf16'), ModelRuntime()
    params = model_mod.init_params(jax.random.key(0), cfg)
    prefill = jax.jit(SS.make_prefill_step(cfg, yoco, rt))
    decode = jax.jit(SS.make_decode_step(cfg, yoco, rt))
    return cfg, params, prefill, decode


def _reference_tokens(req, prompt_len, gen_len, arch=ARCH):
    """Greedy-decode one request alone through the contiguous einsum path:
    the oracle the continuous scheduler must reproduce token-for-token."""
    cfg, params, prefill, decode = _reference_model(arch)
    cache = model_mod.init_cache_tree(cfg, 1, prompt_len + gen_len)
    pad = np.zeros((1, prompt_len), np.int32)
    pad[0, :len(req.prompt)] = req.prompt
    logits, cache = prefill(params, dict(inputs=jnp.asarray(pad)), cache,
                            jnp.asarray([len(req.prompt) - 1]))
    toks = [int(jnp.argmax(logits, -1)[0])]
    pos = len(req.prompt)
    while len(toks) < req.target_gen:
        t, _, cache = decode(params, jnp.asarray([toks[-1]], jnp.int32),
                             jnp.asarray([pos], jnp.int32), cache)
        toks.append(int(t[0]))
        pos += 1
    return toks


def _solo_vs_continuous(arch, *, n=5, prompt_len=16, gen_len=8):
    """Token-for-token solo-vs-continuous parity over a contended stream
    (slots < requests forces eviction + re-admission waves)."""
    out = SV.serve_continuous(arch, slots=2, n_requests=n,
                              prompt_len=prompt_len, gen_len=gen_len,
                              page_size=4, attn_impl='einsum', quiet=True)
    assert out['completed'] == n
    assert out['steps'] > gen_len          # slots < requests => multiple waves
    if out['decode_compilations'] is not None:
        assert out['decode_compilations'] == 1   # no retrace across churn
    cfg = configs.get(arch, smoke=True)
    dc = synthetic.for_arch(cfg, global_batch=n, seq_len=prompt_len)
    prompts = np.asarray(synthetic.make_batch(dc, 0)['inputs'])
    for req in SV._ragged_stream(n, prompt_len, gen_len, prompts):
        want = _reference_tokens(req, prompt_len, gen_len, arch)
        assert out['outputs'][req.rid] == want, (req.rid,
                                                 out['outputs'][req.rid],
                                                 want)


def test_continuous_serve_matches_single_request_reference():
    """5 ragged requests over 2 slots (forced re-admission) with a pool
    tight enough to queue: every emitted token must equal the request's
    solo contiguous-decode tokens."""
    _solo_vs_continuous(ARCH)


@pytest.mark.slow
def test_continuous_serve_matches_single_request_reference_mla():
    """The same token-for-token contract on the MLA family: deepseek-v3
    smoke over the paged latent pool (one cl pool per layer, same block
    tables) must reproduce each request's solo contiguous absorbed
    decode exactly."""
    _solo_vs_continuous(MLA_ARCH, n=4, gen_len=6)


def _preemption_is_lossless(arch, tight_pages):
    kwargs = dict(slots=3, n_requests=5, prompt_len=16, gen_len=8,
                  page_size=4, attn_impl='einsum', quiet=True)
    tight = SV.serve_continuous(arch, num_pages=tight_pages, **kwargs)
    roomy = SV.serve_continuous(arch, num_pages=None, **kwargs)
    assert tight['preempted'] > 0
    assert tight['outputs'] == roomy['outputs']
    assert tight['completed'] == roomy['completed'] == 5
    return tight


def test_continuous_serve_preemption_is_lossless():
    """A pool too small for all lanes preempts-and-requeues; the final
    token streams must be identical to an uncontended run."""
    _preemption_is_lossless(ARCH, 9)


@pytest.mark.slow
def test_continuous_serve_preemption_is_lossless_mla():
    """Forced preemption + recompute re-admission on the paged LATENT
    pool: deepseek token streams must survive the churn unchanged."""
    _preemption_is_lossless(MLA_ARCH, 9)


@pytest.mark.slow
def test_continuous_serve_flash_matches_einsum():
    """The scalar-prefetch paged kernel serves the same stream with the
    same tokens as the densified einsum oracle."""
    kwargs = dict(slots=2, n_requests=3, prompt_len=16, gen_len=6,
                  page_size=4, quiet=True)
    a = SV.serve_continuous(ARCH, attn_impl='einsum', **kwargs)
    b = SV.serve_continuous(ARCH, attn_impl='flash', **kwargs)
    assert a['outputs'] == b['outputs']


@pytest.mark.slow
def test_continuous_serve_flash_matches_einsum_mla():
    """flash_decode_paged_mla serves the same deepseek stream with the
    same tokens as the densified absorbed-einsum oracle."""
    kwargs = dict(slots=2, n_requests=3, prompt_len=16, gen_len=6,
                  page_size=4, quiet=True)
    a = SV.serve_continuous(MLA_ARCH, attn_impl='einsum', **kwargs)
    b = SV.serve_continuous(MLA_ARCH, attn_impl='flash', **kwargs)
    assert a['outputs'] == b['outputs']


# ----------------------------------------------------------------------------
# serving-mode routing table (pinned: which families reach which modes)
# ----------------------------------------------------------------------------
def test_continuous_serve_routing_table():
    """--continuous admits every token-input family — GQA, MLA (fp or
    int8-tiered), SSM, and hybrid — and rejects exactly the non-token
    frontends, each with its own message. The SSM/hybrid block fell with
    the RecurrentLayout slot ops; only the stub frontend's inability to
    requeue non-token prompts remains."""
    # blocked: non-token inputs can't requeue through the stub frontend
    for arch in ('musicgen-large', 'qwen2-vl-72b'):
        with pytest.raises(ValueError, match='token streams'):
            SV.serve_continuous(arch, quiet=True)
    # blocked: pure-SSM recurrent state has no int8 KV tier to quantize
    with pytest.raises(ValueError, match='recurrent state'):
        SV.serve_continuous('mamba2-780m', kv_quant=True, quiet=True)
    # admitted: every token family constructs + drains an empty stream
    # (GQA/MLA fp and int8-tiered, SSM, and hybrid alike — the gate must
    # not regress to a blanket SSM/hybrid block)
    for arch, kv_quant in ((ARCH, False), (ARCH, True), (MLA_ARCH, False),
                           (MLA_ARCH, True), ('mamba2-780m', False),
                           ('zamba2-1.2b', False), ('zamba2-1.2b', True)):
        out = SV.serve_continuous(arch, n_requests=0, prompt_len=8,
                                  gen_len=4, page_size=4,
                                  kv_quant=kv_quant, quiet=True)
        assert out['completed'] == 0


# ----------------------------------------------------------------------------
# sampling (the make_decode_step greedy/non-greedy satellite)
# ----------------------------------------------------------------------------
def test_sample_tokens_top_k_support():
    key = jax.random.key(0)
    logits = jnp.asarray(np.random.RandomState(0).randn(64, 100) * 3)
    top2 = set(np.asarray(jax.lax.top_k(logits, 2)[1]).ravel().tolist())
    toks = SS.sample_tokens(logits, key, temperature=1.0, top_k=2)
    assert set(np.asarray(toks).tolist()) <= top2
    # temperature <= 0 is the greedy limit
    greedy = SS.sample_tokens(logits, key, temperature=0.0)
    np.testing.assert_array_equal(np.asarray(greedy),
                                  np.asarray(jnp.argmax(logits, -1)))


def test_sample_tokens_temperature_sharpens():
    """Low temperature concentrates mass on the argmax."""
    key = jax.random.key(1)
    logits = jnp.asarray(np.random.RandomState(1).randn(256, 32))
    cold = SS.sample_tokens(logits, key, temperature=0.01)
    hot = SS.sample_tokens(logits, key, temperature=5.0)
    am = np.asarray(jnp.argmax(logits, -1))
    agree_cold = float(np.mean(np.asarray(cold) == am))
    agree_hot = float(np.mean(np.asarray(hot) == am))
    assert agree_cold > 0.95, agree_cold
    assert agree_hot < agree_cold


def test_decode_step_sampled_signature_and_determinism():
    """Non-greedy decode steps take a PRNG key and are reproducible under
    the same key; different keys may differ."""
    cfg = configs.get(ARCH, smoke=True)
    yoco, rt = YocoConfig(mode='bf16'), ModelRuntime()
    params = model_mod.init_params(jax.random.key(0), cfg)
    step = SS.make_decode_step(cfg, yoco, rt, greedy=False, temperature=1.0,
                               top_k=8)
    cache = model_mod.init_cache_tree(cfg, 2, 8)
    tok = jnp.array([1, 2], jnp.int32)
    key = jax.random.key(3)
    t1, logits, _ = step(params, tok, jnp.int32(0), cache, key)
    t2, _, _ = step(params, tok, jnp.int32(0), cache, key)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    # sampled ids stay inside the top-k set of the step's own logits
    topk_ids = np.asarray(jax.lax.top_k(logits, 8)[1])
    for b in range(2):
        assert int(t1[b]) in topk_ids[b].tolist()
