"""Serving-telemetry tests (PR 8): histogram math against numpy, lifecycle
spans from scripted event sequences, the EventLog timestamp audit, the
EnergyMeter priced EXACTLY like direct hwmodel calls, metrics-vs-EventLog
cross-checks on real (clean and seeded-chaos) continuous serves, and the
Chrome-trace schema. ``make test-telemetry`` runs this file."""

import json

import numpy as np
import pytest

from repro import configs
from repro.core import hwmodel
from repro.launch import serve
from repro.runtime import faults
from repro.runtime import telemetry as T

pytestmark = pytest.mark.telemetry

ARCH = 'stablelm-1.6b'
SMOKE = dict(slots=3, n_requests=6, prompt_len=16, gen_len=8, page_size=4)


# ----------------------------------------------------------------------------
# metric primitives
# ----------------------------------------------------------------------------
def test_histogram_percentiles_within_bucket_width_of_numpy():
    rng = np.random.default_rng(0)
    vals = rng.lognormal(mean=-6.0, sigma=1.5, size=4000)   # ~ms latencies
    h = T.Histogram('h')
    for v in vals:
        h.observe(float(v))
    bounds = list(h.bounds)
    for q in (0.50, 0.90, 0.99):
        est = h.percentile(q)
        ref = float(np.quantile(vals, q))
        # the estimator is exact to one bucket width at the landing bucket
        i = np.searchsorted(bounds, ref)
        lo = bounds[i - 1] if i > 0 else 0.0
        hi = bounds[i] if i < len(bounds) else float(vals.max())
        assert abs(est - ref) <= (hi - lo) + 1e-12, (q, est, ref)
        assert vals.min() <= est <= vals.max()


def test_histogram_empty_and_single_value():
    h = T.Histogram('h', buckets=(1.0, 2.0))
    assert h.percentile(0.5) is None
    h.observe(1.5)
    # clamped to the observed range: one sample pins every percentile
    for q in (0.0, 0.5, 0.99):
        assert h.percentile(q) == 1.5
    snap = h.snapshot()
    assert snap['count'] == 1 and snap['min'] == snap['max'] == 1.5


def test_histogram_prometheus_render_is_cumulative():
    h = T.Histogram('lat', buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 0.5, 5.0):
        h.observe(v)
    lines = h.render()
    assert 'lat_bucket{le="0.1"} 1' in lines
    assert 'lat_bucket{le="1.0"} 3' in lines
    assert 'lat_bucket{le="+Inf"} 4' in lines       # == _count, always
    assert 'lat_count 4' in lines


def test_counter_gauge_label_discipline():
    reg = T.MetricsRegistry()
    c = reg.counter('reqs', labels=('kind',))
    c.inc(kind='a')
    c.inc(2, kind='b')
    assert c.value(kind='b') == 2 and c.total() == 3
    with pytest.raises(ValueError, match='got labels'):
        c.inc(wrong='x')
    with pytest.raises(ValueError, match='only go up'):
        c.inc(-1, kind='a')
    g = reg.gauge('depth')
    g.set(7)
    g.set(3)
    assert g.value() == 3
    # re-registration under a different type is a bug, not a new metric
    with pytest.raises(ValueError, match='already registered'):
        reg.gauge('reqs')
    assert 'reqs{kind="b"} 2' in reg.render_prometheus()


# ----------------------------------------------------------------------------
# lifecycle spans from the event log
# ----------------------------------------------------------------------------
def _ev(kind, rid, t, **d):
    return dict(kind=kind, rid=rid, t=t, **d)


def test_span_derivation_clean_and_retry_paths():
    log = [
        # rid 1: one admission, finishes
        _ev('submit', 1, 0.0),
        _ev('admit', 1, 2.0, prefill_s=0.5),
        _ev('finish', 1, 10.0, tokens=5),
        # rid 2: preempted once, re-admitted, finishes
        _ev('submit', 2, 1.0),
        _ev('admit', 2, 3.0, prefill_s=0.25),
        _ev('preempt', 2, 4.0),
        _ev('retry', 2, 4.0),
        _ev('admit', 2, 6.0, prefill_s=0.3),
        _ev('finish', 2, 12.0, tokens=4),
        # rid 3: rejected before any admission
        _ev('submit', 3, 5.0),
        _ev('reject', 3, 5.0),
        # rid 4: no terminal yet -> skipped (the audit owns that case)
        _ev('submit', 4, 6.0),
    ]
    spans = {s.rid: s for s in T.derive_request_spans(log)}
    assert set(spans) == {1, 2, 3}

    s1 = spans[1]
    assert (s1.queue_wait_s, s1.ttft_s, s1.service_s) == (2.0, 2.5, 10.0)
    assert s1.itl_s == pytest.approx((10.0 - 2.5) / 4)
    assert s1.tokens == 5 and s1.admits == 1 and s1.retries == 0

    s2 = spans[2]
    assert s2.admits == 2 and s2.retries == 1 and s2.preempts == 1
    assert s2.queue_wait_s == 2.0              # submit -> FIRST admit
    assert s2.ttft_s == pytest.approx(2.25)    # first admit + its prefill
    assert s2.prefill_s == 0.3                 # LAST admission's prefill
    assert s2.itl_s == pytest.approx((12.0 - 6.3) / 3)

    s3 = spans[3]
    assert s3.terminal == 'reject' and s3.queue_wait_s is None
    assert s3.ttft_s is None and s3.itl_s is None and s3.service_s == 0.0


def test_span_derivation_accepts_live_event_log():
    ticks = iter(float(x) for x in range(100))
    log = faults.EventLog(clock=lambda: next(ticks))
    log.emit('submit', step=0, rid=9)                        # t=0
    log.emit('admit', step=1, rid=9, slot=0)                 # t=1
    log.annotate_last('admit', 9, prefill_s=0.5)
    log.emit('quarantine', step=2, rid=9, slot=0)            # t=2
    log.emit('retry', step=2, rid=9)                         # t=3
    log.emit('admit', step=3, rid=9, slot=1)                 # t=4
    log.emit('finish', step=5, rid=9, tokens=3)              # t=5
    (s,) = T.derive_request_spans(log)
    assert (s.quarantines, s.retries, s.admits) == (1, 1, 2)
    assert s.ttft_s == pytest.approx(1.5) and s.service_s == 5.0
    with pytest.raises(ValueError, match='no .* event for rid'):
        log.annotate_last('admit', 404, prefill_s=1.0)


def test_observe_spans_fills_the_catalog():
    reg = T.MetricsRegistry()
    spans = T.derive_request_spans([
        _ev('submit', 1, 0.0), _ev('admit', 1, 1.0, prefill_s=0.1),
        _ev('finish', 1, 3.0, tokens=4),
        _ev('submit', 2, 0.0), _ev('fail', 2, 9.0),
    ])
    T.observe_spans(reg, spans)
    assert reg.get('serve_requests_total').value(terminal='finish') == 1
    assert reg.get('serve_requests_total').value(terminal='fail') == 1
    assert reg.get('serve_tokens_out_total').value() == 4
    assert reg.get('serve_service_seconds').count == 2
    assert reg.get('serve_ttft_seconds').count == 1   # rid 2 never admitted


# ----------------------------------------------------------------------------
# the timestamp audit (satellite a)
# ----------------------------------------------------------------------------
def test_terminal_accounting_rejects_regressing_timestamps():
    ts = iter([0.0, 5.0, 1.0])
    log = faults.EventLog(clock=lambda: next(ts))
    log.emit('submit', step=0, rid=1)
    log.emit('finish', step=1, rid=1, tokens=1)
    log.emit('submit', step=2, rid=2)          # t jumps backward
    with pytest.raises(ValueError, match='timestamps regress'):
        log.terminal_accounting()


def test_terminal_accounting_rejects_post_terminal_activity():
    log = faults.EventLog()
    log.emit('submit', step=0, rid=1)
    log.emit('finish', step=1, rid=1, tokens=1)
    log.emit('admit', step=2, rid=1, slot=0)   # zombie: not a 2nd terminal
    with pytest.raises(ValueError, match='activity after its terminal'):
        log.terminal_accounting()


# ----------------------------------------------------------------------------
# energy meter == direct hwmodel pricing (no new model, just bookkeeping)
# ----------------------------------------------------------------------------
def test_energy_meter_matches_direct_hwmodel_calls_gqa():
    cfg = configs.get(ARCH, smoke=True)
    meter = T.EnergyMeter(cfg, page_size=4, kv_quant=True, hot_window=2)
    steps = [[(5, 0), (9, 1)], [(6, 0), (10, 1), (14, 2)]]
    for lanes in steps:
        meter.observe_step(lanes)
    want_achieved = want_baseline = want_ops = 0.0
    for s_live, cold in [l for lanes in steps for l in lanes]:
        r = hwmodel.decode_kv_traffic(
            s_live, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.resolved_head_dim, page_size=4, hot_window=2,
            cold_blocks=cold)
        want_achieved += r['tiered_pj_per_token'] * cfg.n_layers
        want_baseline += r['baseline_pj_per_token'] * cfg.n_layers
        want_ops += r['ops_per_token'] * cfg.n_layers
    t = meter.totals()
    assert t['tokens'] == 5 and t['n_attn_layers'] == cfg.n_layers
    assert t['achieved_pj'] == want_achieved           # exact, not approx
    assert t['baseline_pj'] == want_baseline
    assert t['ops'] == want_ops
    assert t['effective_tops_w'] == want_ops / want_achieved
    assert t['achieved_bytes'] < t['baseline_bytes']   # the tier pays off
    assert t['paper']['ima_tops_w'] == pytest.approx(123.8, abs=0.05)


def test_energy_meter_untiered_achieved_equals_baseline():
    cfg = configs.get(ARCH, smoke=True)
    meter = T.EnergyMeter(cfg, page_size=4, kv_quant=False)
    meter.observe_step([(5, 0), (9, 3)])   # cold residency ignored untiered
    t = meter.totals()
    assert t['achieved_bytes'] == t['baseline_bytes'] == t['hot_bytes']
    assert t['cold_bytes'] == 0.0 and t['energy_reduction'] == 1.0


def test_energy_meter_hybrid_layer_split_and_state_term():
    cfg = configs.get('zamba2-1.2b', smoke=True)
    from repro.models.ssm import dims as ssm_dims
    meter = T.EnergyMeter(cfg, page_size=4)
    n_attn = cfg.n_layers // cfg.hybrid_group
    assert (meter.n_attn, meter.n_mamba) == (n_attn, cfg.n_layers - n_attn)
    meter.observe_step([(7, 0)])
    s, dm = cfg.ssm, ssm_dims(cfg)
    st = hwmodel.decode_state_traffic(
        conv_elems=(s.conv_width - 1) * dm['conv_dim'],
        ssm_elems=dm['n_heads'] * s.head_dim * s.d_state,
        n_heads=dm['n_heads'], n_layers=meter.n_mamba)
    kv = hwmodel.decode_kv_traffic(
        7, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.resolved_head_dim, page_size=4, hot_window=1,
        cold_blocks=0)
    t = meter.totals()
    assert t['baseline_pj'] == (kv['baseline_pj_per_token'] * n_attn
                                + st['baseline_pj_per_token'])
    assert t['ops'] == (kv['ops_per_token'] * n_attn + st['ops_per_token'])


def test_hwmodel_cold_blocks_override_clamps():
    kw = dict(n_heads=8, n_kv_heads=4, head_dim=64, page_size=4,
              hot_window=1)
    rule = hwmodel.decode_kv_traffic(17, **kw)              # 5 blocks
    assert rule['cold_blocks'] == 4
    measured = hwmodel.decode_kv_traffic(17, cold_blocks=2, **kw)
    assert (measured['cold_blocks'], measured['hot_blocks']) == (2, 3)
    assert measured['tiered_bytes_per_token'] > \
        rule['tiered_bytes_per_token']   # less int8 residency, more fp bytes
    # out-of-range measurements clamp: the write block is never cold
    assert hwmodel.decode_kv_traffic(17, cold_blocks=99,
                                     **kw)['cold_blocks'] == 4
    assert hwmodel.decode_kv_traffic(17, cold_blocks=-3,
                                     **kw)['cold_blocks'] == 0


# ----------------------------------------------------------------------------
# cross-checks on real serves: metrics can never drift from the audit log
# ----------------------------------------------------------------------------
@pytest.fixture(scope='module')
def clean_out():
    return serve.serve_continuous(ARCH, attn_impl='einsum', quiet=True,
                                  **SMOKE)


@pytest.fixture(scope='module')
def chaos_out():
    inj = faults.FaultInjector(seed=7, profile=faults.chaos_profile())
    return serve.serve_continuous(ARCH, attn_impl='einsum', quiet=True,
                                  faults=inj, retry_budget=16,
                                  kv_quant=True, hot_window=2, **SMOKE)


def _counter_values(snap, name):
    return {k: int(v) for k, v in snap['metrics'][name]['values'].items()}


def test_clean_serve_metrics_equal_event_log(clean_out):
    out = clean_out
    snap = out['telemetry']
    from collections import Counter
    assert _counter_values(snap, 'serve_requests_total') == \
        dict(Counter(out['terminal'].values()))
    assert _counter_values(snap, 'serve_events_total') == out['events']
    assert snap['energy']['tokens'] == out['decode_tokens']
    assert int(snap['metrics']['serve_tokens_out_total']['value']) == \
        sum(out['out_lens'].values())
    assert snap['metrics']['serve_step_seconds']['count'] == out['steps']
    assert snap['spans'] == out['requests']
    # report counts themselves are derived from the log (single source)
    assert out['completed'] == sum(
        1 for v in out['terminal'].values() if v == 'finish')
    s = out['telemetry_summary']
    assert s['ttft_p50_s'] > 0 and s['itl_p50_s'] is not None
    assert s['effective_tops_w'] > 0 and s['paper_ima_tops_w'] == 123.8


def test_chaos_soak_metrics_equal_event_log(chaos_out):
    out = chaos_out
    snap = out['telemetry']
    from collections import Counter
    assert _counter_values(snap, 'serve_requests_total') == \
        dict(Counter(out['terminal'].values()))
    assert _counter_values(snap, 'serve_events_total') == out['events']
    # every applied fault event is counted, by name
    faults_total = sum(
        _counter_values(snap, 'serve_faults_total').values())
    assert faults_total == out['events'].get('fault', 0)
    # tier accounting: quantized pages and cold-byte traffic line up
    assert int(snap['metrics']['serve_pages_quantized_total']['value']) == \
        out['pages_quantized']
    e = snap['energy']
    assert e['kv_quant'] is True
    if out['pages_quantized'] > out['pages_quant_dropped']:
        assert e['cold_bytes'] > 0
        assert e['achieved_pj'] < e['baseline_pj']
    assert e['tokens'] == out['decode_tokens']


def test_no_metrics_run_strips_telemetry():
    out = serve.serve_continuous(ARCH, attn_impl='einsum', quiet=True,
                                 metrics=False, **SMOKE)
    assert 'telemetry' not in out and 'telemetry_summary' not in out
    assert out['completed'] == out['requests']   # accounting still derived


# ----------------------------------------------------------------------------
# trace schema (the --trace surface)
# ----------------------------------------------------------------------------
def test_trace_file_is_loadable_chrome_trace(tmp_path):
    path = str(tmp_path / 'serve.trace.json')
    inj = faults.FaultInjector(seed=7, profile=faults.chaos_profile())
    out = serve.serve_continuous(ARCH, attn_impl='einsum', quiet=True,
                                 faults=inj, retry_budget=16,
                                 trace=path, **SMOKE)
    assert out['trace'] == path
    with open(path) as f:
        tr = json.load(f)
    evs = tr['traceEvents']
    assert {e['ph'] for e in evs} <= {'X', 'i', 'M'}
    for e in evs:
        if e['ph'] == 'X':
            assert e['ts'] >= 0 and e['dur'] >= 0
            assert 0 <= e['tid'] <= SMOKE['slots']
    names = {e['name'] for e in evs if e['ph'] == 'X'}
    assert {'prefill', 'decode'} <= names
    # one named track per slot plus the scheduler track
    threads = {e['args']['name'] for e in evs
               if e['ph'] == 'M' and e['name'] == 'thread_name'}
    assert threads == {'scheduler'} | {
        f'slot {s}' for s in range(SMOKE['slots'])}


def test_summarize_none_passthrough():
    assert T.summarize(None) is None
